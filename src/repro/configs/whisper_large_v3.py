"""whisper-large-v3 [audio]: enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified]. 32 enc + 32 dec layers, d1280 20H (kv20)
d_ff=5120 vocab=51866; the audio conv frontend is a STUB (input_specs
provides precomputed 1500-frame embeddings); sinusoidal positions so the
backbone lowers at any decode length."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, d_ff=5120, vocab_size=51866,
    norm="layernorm", act="gelu", tie_embeddings=True,
    encoder_layers=32, encoder_len=1500,
    source="arXiv:2212.04356", remark="enc-dec, conv frontend (stub)",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                         d_ff=128, vocab_size=512, encoder_layers=2,
                         encoder_len=16)

"""h2o-danube-3-4b [dense]: llama+mistral mix, SWA [arXiv:2401.16818;
unverified]. 24L d3840 32H (kv8) d_ff=10240 vocab=32000, window 4096."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense", num_layers=24, d_model=3840,
    num_heads=32, num_kv_heads=8, d_ff=10240, vocab_size=32000,
    sliding_window=4096, rope_theta=10_000.0,
    source="arXiv:2401.16818", remark="llama+mistral mix, SWA",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=512, sliding_window=16)

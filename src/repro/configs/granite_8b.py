"""granite-8b [dense]: llama-arch, code [arXiv:2405.04324; hf].
36L d4096 32H (kv8) d_ff=14336 vocab=49152."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense", num_layers=36, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=49152,
    rope_theta=10_000_000.0,
    source="arXiv:2405.04324", remark="llama-arch, code",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=512)

"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. 24L d2048 16H (kv16) d_expert=1408
vocab=151936; shared expert width 4x1408=5632."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=151936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  num_shared_experts=4, shared_d_ff=5632),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B", remark="4 shared + 60 routed top-4",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                         d_ff=96, vocab_size=512,
                         moe=MoEConfig(num_experts=8, top_k=4, d_expert=96,
                                       num_shared_experts=1, shared_d_ff=128))

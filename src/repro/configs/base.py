"""Architecture config system + registry + the assigned input-shape sets.

Every assigned architecture registers an `ArchConfig` via its module in this
package; `get_config(name)` / `list_archs()` are the public API, and
`--arch <id>` on the launchers resolves through here. `reduced()` yields the
small-family config used by the per-arch CPU smoke tests (full configs are
only ever lowered abstractly in the dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN width
    num_shared_experts: int = 0
    shared_d_ff: int = 0  # total width of the always-on shared expert
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    dispatch: str = "sort"  # "sort" (GFTR pattern) | "einsum" (dense baseline)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    num_heads: int = 4
    slstm_every: int = 2  # one sLSTM block per this many blocks (rest mLSTM)
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 500_000.0
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2-style): shared attention block applied every N ssm blocks
    shared_attn_every: int = 0
    # vlm: one cross-attn layer per this many self-attn layers
    cross_attn_every: int = 0
    vision_tokens: int = 1601  # stub patch-embedding count (llama-3.2-vision)
    # enc-dec (whisper): encoder layer count; frontend is a stub that provides
    # precomputed frame embeddings of length `encoder_len`.
    encoder_layers: int = 0
    encoder_len: int = 1500
    pad_vocab_to: int = 128  # pad vocab so TP sharding divides
    remark: str = ""
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to
        return -(-self.vocab_size // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/linear-recurrent families or SWA."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape sets (assigned): seq_len x global_batch
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "xlstm-125m",
    "qwen2-moe-a2.7b",
    "mixtral-8x7b",
    "zamba2-2.7b",
    "olmo-1b",
    "granite-8b",
    "starcoder2-7b",
    "h2o-danube-3-4b",
    "llama-3.2-vision-11b",
    "whisper-large-v3",
]

_MODULES = {
    "xlstm-125m": "xlstm_125m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "olmo-1b": "olmo_1b",
    "granite-8b": "granite_8b",
    "starcoder2-7b": "starcoder2_7b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "whisper-large-v3": "whisper_large_v3",
}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_reduced_config(name: str) -> ArchConfig:
    """Small same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.REDUCED


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def cell_is_runnable(arch: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Shape-cell applicability (skips documented in DESIGN.md)."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    if shape.name == "long_500k" and arch.family == "audio":
        return False, "long_500k is semantically void for the 30s-audio enc-dec backbone"
    return True, ""

"""zamba2-2.7b [hybrid]: Mamba2 + shared attn blocks [arXiv:2411.15242; hf].
54 Mamba2 layers d2560, ssm_state=64; one *shared* (single-copy) attention
block (32H kv32, d_ff=10240) applied every 6 mamba layers."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=128),
    shared_attn_every=6,
    source="arXiv:2411.15242", remark="Mamba2 + shared attn blocks",
)

REDUCED = CONFIG.replace(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                         d_ff=128, vocab_size=512, shared_attn_every=2,
                         ssm=SSMConfig(state_dim=8, head_dim=8, expand=2,
                                       conv_width=4, chunk=8))

"""xlstm-125m [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
12L d_model=768 4H d_ff=0 (xLSTM blocks carry their own projections)
vocab=50304. Alternating mLSTM/sLSTM pairs (slstm_every=2)."""
from .base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", num_layers=12, d_model=768,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(num_heads=4, slstm_every=2), tie_embeddings=True,
    source="arXiv:2405.04517", remark="sLSTM + mLSTM blocks",
)

REDUCED = CONFIG.replace(num_layers=4, d_model=64, vocab_size=512,
                         xlstm=XLSTMConfig(num_heads=2, slstm_every=2))

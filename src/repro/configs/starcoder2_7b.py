"""starcoder2-7b [dense]: GQA, RoPE [arXiv:2402.19173; hf].
32L d4608 36H (kv4) d_ff=18432 vocab=49152; LayerNorm + GELU MLP."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense", num_layers=32, d_model=4608,
    num_heads=36, num_kv_heads=4, d_ff=18432, vocab_size=49152,
    norm="layernorm", act="gelu", rope_theta=100_000.0,
    source="arXiv:2402.19173", remark="GQA, RoPE",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=512)
